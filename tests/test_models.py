"""Model-layer unit tests: attention schedules, SSM/RG-LRU recurrences,
MoE dispatch invariants, rope variants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm
from repro.models.attention import (attention_decode, attention_forward,
                                    init_attention, _project_qkv)
from repro.models.config import (ModelConfig, MoEConfig, RGLRUConfig,
                                 SSMConfig)
from repro.models.moe import capacity, init_moe, moe_forward
from repro.models.rglru import (init_rglru, init_rglru_cache, rglru_decode,
                                rglru_forward)
from repro.models.ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_forward


def _attn_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=1, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=100,
                head_dim=8)
    base.update(kw)
    return ModelConfig(**base)


def _naive_attention(params, x, positions, cfg):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, x, positions, cfg)
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * hd ** -0.5
    dq = positions[:, None, None, :, None]
    dk = positions[:, None, None, None, :]
    mask = jnp.ones_like(logits, bool)
    if cfg.causal:
        mask &= dk <= dq
    if cfg.sliding_window is not None:
        mask &= dq - dk < cfg.sliding_window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v).reshape(b, s, h * hd)
    return out @ params["wo"]


ATTN_VARIANTS = [
    ("causal", {}),
    ("qknorm", dict(qk_norm=True)),
    ("window", dict(sliding_window=16)),
    ("encoder", dict(causal=False, rope="none")),
    ("rope2d", dict(rope="rope2d")),
    ("mrope", dict(rope="mrope")),
    ("bias", dict(attn_bias=True)),
    ("mqa", dict(num_kv_heads=1)),
]


@pytest.mark.parametrize("name,kw", ATTN_VARIANTS)
@pytest.mark.parametrize("impl", ["masked", "triangular"])
def test_attention_impls_match_naive(name, kw, impl):
    cfg = _attn_cfg(**kw)
    if impl == "triangular" and not cfg.causal:
        pytest.skip("triangular is causal-only")
    params = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    ref = _naive_attention(params, x, pos, cfg)
    y, _ = attention_forward(params, x, pos, cfg, impl=impl, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=3e-4)


def test_banded_matches_naive_windowed():
    cfg = _attn_cfg(sliding_window=16)
    params = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    ref = _naive_attention(params, x, pos, cfg)
    y, _ = attention_forward(params, x, pos, cfg, impl="banded", chunk=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=3e-4)


def test_attention_unroll_identical():
    """unroll=True is an analysis knob: results must be bit-comparable."""
    cfg = _attn_cfg()
    params = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    y1, _ = attention_forward(params, x, pos, cfg, impl="masked", chunk=16)
    y2, _ = attention_forward(params, x, pos, cfg, impl="masked", chunk=16,
                              unroll=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_ring_buffer_decode_any_prefill_length():
    cfg = _attn_cfg(sliding_window=8)
    params = init_attention(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 32))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    ref, _ = attention_forward(params, x, pos, cfg, impl="masked", chunk=8)
    for half in (12, 17, 20):
        y0, cache = attention_forward(params, x[:, :half], pos[:, :half],
                                      cfg, chunk=4, return_cache=True)
        ys = [y0]
        for t in range(half, s):
            yt, cache = attention_decode(params, x[:, t:t + 1], cache,
                                         jnp.int32(t), cfg)
            ys.append(yt)
        err = float(jnp.abs(jnp.concatenate(ys, 1) - ref).max())
        assert err < 3e-4, (half, err)


# --- SSM ---------------------------------------------------------------

def _ssm_cfg(chunk=8):
    return ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                       num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=100,
                       ssm=SSMConfig(state_dim=8, head_dim=8, expand=2,
                                     chunk=chunk))


def test_ssd_chunked_equals_sequential():
    cfg = _ssm_cfg()
    params = init_ssm(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    u = jax.random.normal(jax.random.PRNGKey(1), (b, s, 32)) * 0.5
    cache = init_ssm_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        yt, cache = ssm_decode(params, u[:, t:t + 1], cache, cfg)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    y_par, st = ssm_forward(params, u, cfg, return_state=True)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(cache["h"]),
                               atol=1e-4)


@pytest.mark.parametrize("chunk", [4, 16, 32])
def test_ssd_chunk_size_invariance(chunk):
    cfg0 = _ssm_cfg(8)
    params = init_ssm(jax.random.PRNGKey(0), cfg0)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32)) * 0.5
    ref, _ = ssm_forward(params, u, cfg0)
    out, _ = ssm_forward(params, u, _ssm_cfg(chunk))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


# --- RG-LRU -------------------------------------------------------------

def test_rglru_scan_equals_sequential():
    cfg = ModelConfig(name="t", family="hybrid", num_layers=3, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=100,
                      head_dim=8, rglru=RGLRUConfig(lru_width=48))
    params = init_rglru(jax.random.PRNGKey(0), cfg)
    b, s = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 32)) * 0.5
    cache = init_rglru_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        yt, cache = rglru_decode(params, x[:, t:t + 1], cache, cfg)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, axis=1)
    y_par, st = rglru_forward(params, x, cfg, return_state=True)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(cache["h"]),
                               atol=1e-4)


def test_rglru_decay_bounded():
    """RG-LRU decay must stay in (0, 1) -- the stability invariant."""
    cfg = ModelConfig(name="t", family="hybrid", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=10,
                      head_dim=8, rglru=RGLRUConfig(lru_width=16))
    params = init_rglru(jax.random.PRNGKey(0), cfg)
    from repro.models.rglru import _lru_coeffs

    u = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16)) * 10
    log_a, _ = _lru_coeffs(params, u, cfg.rglru.c_exponent)
    a = np.asarray(jnp.exp(log_a))
    assert (a > 0).all() and (a < 1).all()


# --- MoE ----------------------------------------------------------------

def test_moe_dropless_equals_dense_computation():
    """With ample capacity, sort-based dispatch == explicit per-token FFN."""
    moe = MoEConfig(num_experts=4, top_k=2, d_expert=16, num_shared=0,
                    capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(0), 8, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    y, aux = moe_forward(params, x, moe)
    assert float(aux["drop_fraction"]) == 0.0

    # explicit reference: per-token loop over its top-k experts
    xf = np.asarray(x.reshape(16, 8))
    logits = xf @ np.asarray(params["router"])
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    ref = np.zeros_like(xf)
    for t in range(16):
        top = np.argsort(-probs[t])[:2]
        ps = probs[t][top] / probs[t][top].sum()
        for p_w, e_idx in zip(ps, top):
            wg = np.asarray(params["we_gate"][e_idx])
            wu = np.asarray(params["we_up"][e_idx])
            wd = np.asarray(params["we_down"][e_idx])
            g = xf[t] @ wg
            u = xf[t] @ wu
            h = g / (1 + np.exp(-g)) * u
            ref[t] += p_w * (h @ wd)
    np.testing.assert_allclose(np.asarray(y).reshape(16, 8), ref,
                               atol=2e-5)


def test_moe_capacity_drops_tokens():
    moe = MoEConfig(num_experts=2, top_k=1, d_expert=8, num_shared=0,
                    capacity_factor=0.25)
    params = init_moe(jax.random.PRNGKey(0), 8, moe, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 8))
    _, aux = moe_forward(params, x, moe)
    assert float(aux["drop_fraction"]) > 0.0


def test_moe_capacity_formula():
    moe = MoEConfig(num_experts=8, top_k=2, d_expert=4,
                    capacity_factor=1.25)
    c = capacity(1024, moe)
    assert c >= 1024 * 2 * 1.25 / 8
    assert c % 4 == 0
