"""Plan/executor layer: prepare a graph once, embed it many times.

The paper's contribution is eliminating redundant work on sparse graphs,
yet a naive client redoes the *same* O(E) preparation -- symmetrize,
self-loop augmentation, the degree fold, the Laplacian edge reweighting,
ELL packing, the chunk manifest -- on every fit, every option setting of
an ensemble sweep, and every ``--compare`` cell.  One-Hot GEE
(arXiv 2109.13098) shows the embedding itself is a cheap linear pass, so
that preparation dominates repeated fits; Edge-Parallel GEE
(arXiv 2402.04403) gets its speedup precisely by hoisting graph prep out
of the per-run path.  This module makes that structural:

  ``PreparedGraph``  an immutable wrapper over ``EdgeList`` that lazily
                     computes and memoizes every derived artifact, so a
                     second fit, another option setting, an ensemble
                     replicate, or a ``--compare`` sweep never re-derives
                     them.
  ``GEEPlan``        resolves ``(backend="auto", opts, device)`` into
                     explicit stages -- prep, scatter/SpMM, epilogue --
                     and executes them against a labels vector.  The
                     epilogue always runs through ``repro.core.epilogue``
                     (the single numerics source of truth).
  ``select_backend`` the cost model behind ``backend="auto"``: Pallas
                     on a real MXU with lane-sized K, ``chunked`` when
                     the working-set estimate exceeds the memory budget,
                     ``sparse_jax`` otherwise.
  ``sweep_options``  the many-settings fast path: correlation is a pure
                     row postprocess, so the 8 canonical option settings
                     need only 4 scatter passes over shared prep.

``gee()``, ``GEEEmbedder``, the ensemble clusterer, the distributed
sharder and the launch CLIs are all thin consumers of this layer.

>>> import numpy as np
>>> from repro.core.gee import ALL_OPTION_SETTINGS, GEEOptions
>>> prep = PreparedGraph.from_arrays(     # symmetrized + uploaded ONCE
...     np.array([0, 1, 2]), np.array([1, 2, 3]), None, num_nodes=4)
>>> labels = np.array([0, 1, 0, 1], np.int32)
>>> plan = GEEPlan.build(prep, 2, GEEOptions(laplacian=True, diag_aug=True,
...                                          correlation=True))
>>> [s.name for s in plan.stages]
['effective_edges', 'segment_scatter', 'row_l2_normalize']
>>> plan.execute(labels).shape
(4, 2)
>>> zs = sweep_options(prep, labels, 2)   # all 8 settings, prep shared
>>> len(zs), zs[GEEOptions(correlation=True)].shape
(8, (4, 2))
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Iterable, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import epilogue
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.core.gee import (ALL_OPTION_SETTINGS, GEEOptions, gee_dense_jax,
                            gee_python_loop, gee_scipy, gee_sparse_jax,
                            laplacian_edge_weights)
from repro.graph.containers import (EdgeList, add_self_loops,
                                    edge_list_from_numpy, symmetrize)

KNOWN_BACKENDS = ("sparse_jax", "pallas", "chunked", "streamed_sharded",
                  "dense_jax", "scipy", "python_loop")

# Working-set budget for the cost model's route-to-chunked decision.
ENV_MEMORY_BUDGET = "REPRO_GEE_MEMORY_BUDGET_BYTES"
DEFAULT_MEMORY_BUDGET = 16 << 30    # 16 GiB: a generous laptop/host default

# The Pallas kernel pays off only while the one-hot fits a few 128-lanes.
PALLAS_MAX_CLASSES = 4 * 128


@jax.jit
def _laplacian_fold(edges: EdgeList) -> EdgeList:
    """Fold d_i^{-1/2} d_j^{-1/2} into the edge weights (device, jitted)."""
    return dataclasses.replace(edges,
                               weight=laplacian_edge_weights(edges))


_add_self_loops_jit = jax.jit(add_self_loops)


def _block_tree(x):
    """``jax.block_until_ready`` tolerant of host-only stage results
    (chunk manifests, numpy triples): tracing-mode stage timings must not
    crash on objects with nothing to wait for."""
    try:
        return jax.block_until_ready(x)
    except Exception:
        return x


def _chunk_key(chunk_edges: int | None) -> int:
    """The ``("chunked", ...)`` cache-key component for a window size."""
    from repro.graph.io import DEFAULT_CHUNK_EDGES

    return int(chunk_edges or DEFAULT_CHUNK_EDGES)


# ---------------------------------------------------------------------------
# PreparedGraph: the memoized prep artifacts
# ---------------------------------------------------------------------------

class PreparedGraph:
    """Immutable wrapper over an ``EdgeList`` memoizing derived artifacts.

    Artifacts (all lazy, each computed at most once per instance):

      * ``with_self_loops()``          the diag-aug edge list (A + I)
      * ``degrees(diag_aug)``          weighted degrees of the (augmented)
                                       graph
      * ``effective_edges(opts)``      self-loop-augmented AND
                                       Laplacian-folded edges -- the exact
                                       input of the scatter stage, keyed
                                       on ``(diag_aug, laplacian)`` (the
                                       correlation flag never affects prep)
      * ``ell(diag_aug)`` /
        ``bucketed_ell(diag_aug)``     the Pallas kernel's packing planes
      * ``chunked(chunk_edges)``       the chunk manifest of the streaming
                                       backend
      * ``host_arrays()``              the valid-prefix numpy triple the
                                       SciPy / python-loop backends consume

    The wrapped ``EdgeList`` must not be mutated afterwards (they are
    frozen dataclasses; nothing in the repo mutates them).
    """

    def __init__(self, edges: EdgeList):
        if isinstance(edges, PreparedGraph):
            raise TypeError("already a PreparedGraph; use PreparedGraph.wrap")
        if not isinstance(edges, EdgeList):
            raise TypeError(f"expected an EdgeList, got "
                            f"{type(edges).__name__}")
        self._edges = edges
        self._cache: Dict[tuple, object] = {}
        self._hits = 0
        self._misses = 0

    # -- construction --------------------------------------------------------
    @staticmethod
    def wrap(graph: "PreparedGraph | EdgeList") -> "PreparedGraph":
        """Idempotent constructor: wrap an ``EdgeList``, pass a
        ``PreparedGraph`` through untouched (preserving its caches)."""
        return graph if isinstance(graph, PreparedGraph) \
            else PreparedGraph(graph)

    @staticmethod
    def from_arrays(src, dst, weight=None, num_nodes: int | None = None,
                    undirected: bool = True,
                    pad_to: int | None = None) -> "PreparedGraph":
        """Build from raw host arrays: symmetrize (for undirected input)
        and upload exactly once -- the cold-start prep a per-call sweep
        would otherwise repeat."""
        src = np.asarray(src)
        dst = np.asarray(dst)
        n = int(num_nodes if num_nodes is not None
                else max(int(src.max(initial=-1)),
                         int(dst.max(initial=-1))) + 1)
        edges = edge_list_from_numpy(
            src, dst, None if weight is None else np.asarray(weight), n,
            pad_to=pad_to)
        if undirected:
            edges = symmetrize(edges)
        return PreparedGraph(edges)

    # -- basics --------------------------------------------------------------
    @property
    def base(self) -> EdgeList:
        """The wrapped (already-directed) edge list."""
        return self._edges

    @property
    def num_nodes(self) -> int:
        return self._edges.num_nodes

    @property
    def num_edges(self) -> int:
        return self._edges.num_edges

    def _memo(self, key: tuple, build):
        hit = self._cache.get(key)
        if hit is not None:
            self._hits += 1
            return hit
        self._misses += 1
        value = build()
        self._cache[key] = value
        return value

    def is_cached(self, key: tuple) -> bool:
        return key in self._cache

    def cache_info(self) -> dict:
        """Which artifacts are resident, plus hit/miss counters (the
        no-rebuild regression tests key on this)."""
        return {"keys": tuple(sorted(map(str, self._cache))),
                "entries": len(self._cache),
                "hits": self._hits, "misses": self._misses}

    # -- prep artifacts ------------------------------------------------------
    def with_self_loops(self) -> EdgeList:
        """The diagonal-augmented list (A + I), spliced after the valid
        prefix exactly like ``repro.graph.containers.add_self_loops``."""
        return self._memo(("self_loops",),
                          lambda: _add_self_loops_jit(self._edges))

    def augmented(self, diag_aug: bool) -> EdgeList:
        return self.with_self_loops() if diag_aug else self._edges

    def degrees(self, diag_aug: bool = False) -> jax.Array:
        """Weighted out-degrees of the (augmented) graph, [N] f32."""
        def build():
            e = self.augmented(diag_aug)
            return jax.ops.segment_sum(e.weight, e.src,
                                       num_segments=e.num_nodes)
        return self._memo(("degrees", bool(diag_aug)), build)

    def laplacian_inv_sqrt(self, diag_aug: bool = False) -> jax.Array:
        """d^{-1/2} of the (augmented) degrees, shared-epilogue clamped."""
        return self._memo(
            ("dinv", bool(diag_aug)),
            lambda: epilogue.inv_sqrt_degrees(self.degrees(diag_aug)))

    def effective_edges(self, opts: GEEOptions) -> EdgeList:
        """The scatter stage's exact input: self loops appended when
        ``opts.diag_aug``, weights Laplacian-folded when ``opts.laplacian``
        (degrees of the *augmented* graph, per the shared option order).
        Keyed on ``(diag_aug, laplacian)`` only -- correlation is pure
        epilogue and never invalidates prep.
        """
        key = ("eff", bool(opts.diag_aug), bool(opts.laplacian))

        def build():
            e = self.augmented(opts.diag_aug)
            return _laplacian_fold(e) if opts.laplacian else e
        return self._memo(key, build)

    def ell(self, diag_aug: bool = False):
        """Single-plane ELL packing of the (augmented) graph (host-side
        O(E); by far the most expensive prep artifact -- cache pays)."""
        from repro.graph.ell import edges_to_ell  # deferred: keep core light

        return self._memo(("ell", bool(diag_aug)),
                          lambda: edges_to_ell(self.augmented(diag_aug)))

    def bucketed_ell(self, diag_aug: bool = False):
        """Degree-bucketed ELL packing of the (augmented) graph."""
        from repro.graph.ell import edges_to_bucketed_ell

        return self._memo(
            ("bucketed_ell", bool(diag_aug)),
            lambda: edges_to_bucketed_ell(self.augmented(diag_aug)))

    def chunked(self, chunk_edges: int | None = None):
        """The streaming backend's chunk manifest over the valid prefix
        (one manifest per distinct window size)."""
        from repro.graph.io import DEFAULT_CHUNK_EDGES, ChunkedEdgeList

        chunk = int(chunk_edges or DEFAULT_CHUNK_EDGES)
        return self._memo(
            ("chunked", chunk),
            lambda: ChunkedEdgeList.from_edge_list(self._edges, chunk))

    def host_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Valid-prefix ``(src, dst, weight)`` numpy triple (the SciPy and
        python-loop backends' input)."""
        return self._memo(("host",), self._edges.valid_arrays)


# ---------------------------------------------------------------------------
# the cost model behind backend="auto"
# ---------------------------------------------------------------------------

def _bucketed_slot_estimate(edges: EdgeList) -> int:
    """Total ELL slots after degree-bucketed packing of the augmented
    graph (host-side O(E) bincount; the pow2 ladder is the packer's own).

    On a skewed (power-law) degree distribution this is the number that
    actually sizes the Pallas working set: every row occupies its
    bucket's full width, so a graph whose *edge count* fits the budget
    can still blow past it after packing (a hub row of degree d costs
    pow2(d) slots; the long tail of degree-1 rows cost 8 slots each).
    """
    from repro.graph.ell import bucket_widths  # the ladder the packer uses

    e = edges.num_edges
    src = np.asarray(edges.src)[:e]
    w = np.asarray(edges.weight)[:e]
    deg = np.bincount(src[w != 0], minlength=edges.num_nodes) + 1  # + loop
    widths = np.asarray(bucket_widths(int(deg.max(initial=1))))
    return int(widths[np.searchsorted(widths, deg)].sum())


def estimate_working_set_bytes(graph: PreparedGraph | EdgeList,
                               num_classes: int, *,
                               backend: str = "sparse_jax") -> int:
    """Rough in-memory working set, per backend family.

    The default (``sparse_jax``) counts base + effective edge triples
    (src/dst/weight, self loops included), the degree vector, and Z.
    ``backend="pallas"`` instead counts the *post-packing* ELL slots
    (:func:`_bucketed_slot_estimate`): cols + vals + the ylab/contrib
    planes are 16 bytes per slot, and on skewed degree distributions
    slots >> E -- the raw edge estimate would route graphs to ``pallas``
    that cannot fit after bucketed packing.
    """
    edges = graph.base if isinstance(graph, PreparedGraph) else graph
    n = edges.num_nodes
    base_bytes = 3 * 4 * edges.padded_size
    z_deg_bytes = 4 * n + 4 * n * int(num_classes)
    if backend == "pallas":
        if isinstance(graph, PreparedGraph):
            slots = graph._memo(("ell_slots",),
                                lambda: _bucketed_slot_estimate(edges))
        else:
            slots = _bucketed_slot_estimate(edges)
        return base_bytes + 16 * slots + z_deg_bytes
    e_eff = edges.padded_size + n                    # with self loops
    return base_bytes + 3 * 4 * e_eff + z_deg_bytes


def memory_budget_bytes() -> int:
    """The route-to-chunked threshold: ``REPRO_GEE_MEMORY_BUDGET_BYTES``
    or a 16 GiB default."""
    return int(os.environ.get(ENV_MEMORY_BUDGET, DEFAULT_MEMORY_BUDGET))


def select_backend(graph: PreparedGraph | EdgeList, num_classes: int, *,
                   device: str | None = None,
                   budget_bytes: int | None = None,
                   num_devices: int | None = None) -> str:
    """The ``backend="auto"`` cost model.

    1. If the estimated working set exceeds the memory budget, stream:
       ``streamed_sharded`` when more than one device can fold disjoint
       sub-windows in parallel, ``chunked`` on a single device -- either
       way peak memory is O(window + N*K) whatever E is.
    2. On a real TPU with K within a few 128-lanes *and* the ELL-aware
       pallas estimate also inside the budget (bucketed packing can blow
       up far past E on skewed degree distributions), the Pallas kernel
       wins the contraction.
    3. Everywhere else, the O(E) segment-sum path is the safe default (on
       CPU the kernel would run in interpret mode, strictly slower).

    ``auto`` never selects ``distributed`` or the host reference backends:
    those change *where the data lives*, which is the caller's decision
    (``streamed_sharded`` builds its own default mesh over the local
    devices, so it stays a pure capacity decision).
    ``num_devices=None`` asks jax for the local device count.
    """
    device = device or jax.default_backend()
    budget = memory_budget_bytes() if budget_bytes is None else budget_bytes
    if estimate_working_set_bytes(graph, num_classes) > budget:
        p = jax.device_count() if num_devices is None else int(num_devices)
        return "streamed_sharded" if p > 1 else "chunked"
    if (device == "tpu" and num_classes <= PALLAS_MAX_CLASSES
            and estimate_working_set_bytes(
                graph, num_classes, backend="pallas") <= budget):
        return "pallas"
    return "sparse_jax"


def select_fused(backend: str, opts: GEEOptions, *,
                 device: str | None = None) -> bool:
    """The fused-epilogue stage's cost model (``fused="auto"``).

    The fused megakernel (``repro.kernels.gee_fused``) replaces the
    staged scatter + epilogue of the ``pallas`` backend, eliminating one
    full [N, K] materialization -- it pays off exactly when (a) the
    backend is ``pallas``, (b) there is an epilogue to fuse (diag-aug or
    correlation; with neither the fused kernel degenerates to the staged
    scatter), and (c) the device is a real TPU (off-TPU both paths run in
    interpret mode and fusion saves nothing).  ``REPRO_GEE_FUSED=1/0``
    overrides (b) and (c) but never (a): the fused stage only exists on
    the Pallas path, so the override is a no-op for other backends.
    """
    if backend != "pallas":
        return False
    from repro.kernels.gee_fused import fused_override  # deferred: keep light

    override = fused_override()
    if override is not None:
        return bool(override)
    device = device or jax.default_backend()
    return device == "tpu" and bool(opts.diag_aug or opts.correlation)


# ---------------------------------------------------------------------------
# GEEPlan: resolved stages + executor
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanStage:
    """One resolved execution stage (introspection / logging surface)."""

    kind: str            # "prep" | "compute" | "epilogue"
    name: str
    cached: bool = False  # artifact already resident in the PreparedGraph
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class GEEPlan:
    """An executable embedding plan: resolved backend + staged pipeline.

    Build once with :meth:`build` (which resolves ``backend="auto"``
    through the cost model), then :meth:`execute` against any labels
    vector.  All prep flows through the shared :class:`PreparedGraph`, so
    repeated executions -- other option settings, ensemble replicates,
    refreshed labels -- reuse every artifact.
    """

    prepared: PreparedGraph
    num_classes: int
    opts: GEEOptions
    backend: str                      # resolved; never "auto"
    chunk_edges: Optional[int] = None
    impl: str = "auto"                # epilogue row-norm impl
    fused: bool = False               # pallas-only: fused-epilogue megakernel
    # streaming backends only: windows staged ahead by background threads
    # (resolved by build(); None defers to the env default at execute time)
    prefetch_windows: Optional[int] = None
    # per-stage wall times (ms) of the last *traced* execution; a mutable
    # cell on a frozen plan -- excluded from eq/repr, never reassigned
    _timings: dict = dataclasses.field(default_factory=dict, compare=False,
                                       repr=False)

    @staticmethod
    def build(graph: PreparedGraph | EdgeList, num_classes: int,
              opts: GEEOptions = GEEOptions(), *, backend: str = "auto",
              device: str | None = None, chunk_edges: int | None = None,
              budget_bytes: int | None = None, impl: str = "auto",
              fused: "bool | str" = "auto",
              prefetch_windows: int | None = None) -> "GEEPlan":
        prepared = PreparedGraph.wrap(graph)
        if backend == "auto":
            backend = select_backend(prepared, num_classes, device=device,
                                     budget_bytes=budget_bytes)
        if backend not in KNOWN_BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; known: {KNOWN_BACKENDS} "
                f"(+ 'auto'; 'distributed' needs an explicit mesh -- use "
                f"GEEEmbedder, or 'streamed_sharded' for the default mesh)")
        if fused == "auto":
            fused = select_fused(backend, opts, device=device)
        if backend in ("chunked", "streamed_sharded"):
            from repro.graph.prefetch import resolve_prefetch_depth
            prefetch_windows = resolve_prefetch_depth(prefetch_windows)
        else:
            prefetch_windows = None      # knob only exists for streaming
        return GEEPlan(prepared=prepared, num_classes=int(num_classes),
                       opts=opts, backend=backend, chunk_edges=chunk_edges,
                       impl=impl, fused=bool(fused) and backend == "pallas",
                       prefetch_windows=prefetch_windows)

    # -- introspection -------------------------------------------------------
    @property
    def _prefetch_detail(self) -> str:
        """Human-readable prefetch depth for ``stages``/``describe()``."""
        return "env" if self.prefetch_windows is None \
            else str(self.prefetch_windows)

    @property
    def stages(self) -> Tuple[PlanStage, ...]:
        p, o = self.prepared, self.opts
        out = []
        if self.backend == "sparse_jax":
            out.append(PlanStage(
                "prep", "effective_edges",
                cached=p.is_cached(("eff", o.diag_aug, o.laplacian)),
                detail="self-loop augment + laplacian fold"))
            out.append(PlanStage("compute", "segment_scatter",
                                 detail="flat segment-sum, O(E)"))
        elif self.backend == "pallas":
            # fused packs the *base* graph (diag-aug folds in as deg+1 +
            # the in-kernel addend); staged packs the augmented graph
            packed_aug = o.diag_aug and not self.fused
            out.append(PlanStage(
                "prep", "bucketed_ell",
                cached=p.is_cached(("bucketed_ell", packed_aug)),
                detail="degree-bucketed ELL packing (host, O(E))"))
            if self.fused:
                out.append(PlanStage(
                    "compute", "gee_spmm_fused",
                    detail="scatter + diag-aug + row-norm fused in VMEM"))
            else:
                out.append(PlanStage(
                    "compute", "gee_spmm",
                    detail="MXU one-hot contraction per bucket"))
        elif self.backend == "chunked":
            from repro.graph.io import DEFAULT_CHUNK_EDGES

            chunk = int(self.chunk_edges or DEFAULT_CHUNK_EDGES)
            out.append(PlanStage("prep", "chunk_manifest",
                                 cached=p.is_cached(("chunked", chunk)),
                                 detail=f"window={chunk} edges, "
                                        f"prefetch={self._prefetch_detail}"))
            out.append(PlanStage("compute", "two_pass_stream",
                                 detail="degree fold + per-class fold"))
        elif self.backend == "streamed_sharded":
            from repro.graph.io import DEFAULT_CHUNK_EDGES

            chunk = int(self.chunk_edges or DEFAULT_CHUNK_EDGES)
            out.append(PlanStage(
                "prep", "chunk_manifest",
                cached=p.is_cached(("chunked", chunk)),
                detail=f"window={chunk} edges, "
                       f"prefetch={self._prefetch_detail}, "
                       f"split across devices"))
            out.append(PlanStage(
                "compute", "window_shard_fold",
                detail="per-device sub-window fold, donated partials"))
            out.append(PlanStage(
                "epilogue", "reduce_scatter_epilogue",
                detail="psum_scatter + row-local diag-aug/row-norm"))
        elif self.backend == "dense_jax":
            out.append(PlanStage("compute", "dense_matmul",
                                 detail="A @ W oracle, O(N^2)"))
        else:                          # scipy / python_loop host references
            out.append(PlanStage("prep", "host_arrays",
                                 cached=p.is_cached(("host",)),
                                 detail="valid-prefix numpy triple"))
            out.append(PlanStage("compute", self.backend))
        if o.correlation and not self.fused \
                and self.backend not in ("chunked", "streamed_sharded",
                                         "dense_jax", "scipy",
                                         "python_loop"):
            out.append(PlanStage("epilogue", "row_l2_normalize",
                                 detail=f"impl={self.impl}"))
        return tuple(out)

    def describe(self, timings: bool = False) -> str:
        """One line per stage, e.g. for ``--plan`` CLI output.

        ``timings=True`` appends each stage's wall time from the last
        *traced* execution (run :meth:`execute` with the tracer enabled
        first -- untraced executions skip the stage-boundary syncs that
        make per-stage times honest, so they record nothing).
        """
        head = (f"GEEPlan(backend={self.backend}"
                + (", fused" if self.fused else "")
                + f", opts={self.opts.tag()}, "
                f"N={self.prepared.num_nodes}, "
                f"E={self.prepared.num_edges}, K={self.num_classes})")
        timed = self._timings if timings else {}
        lines = [head]
        for s in self.stages:
            line = (f"  [{s.kind:8s}] {s.name}"
                    + (" (cached)" if s.cached else "")
                    + (f" -- {s.detail}" if s.detail else ""))
            if s.name in timed:
                line += f"  [{timed[s.name]:.2f} ms]"
            lines.append(line)
        if timings:
            if "total_ms" in timed:
                lines.append(f"  total {timed['total_ms']:.2f} ms "
                             f"(stage syncs forced by tracing)")
            else:
                lines.append("  (no traced execution yet: enable the "
                             "tracer, then execute())")
        return "\n".join(lines)

    @property
    def last_timings(self) -> dict:
        """``{stage_name: ms, "total_ms": ms}`` from the last traced
        execution (empty until one happens)."""
        return dict(self._timings)

    # -- execution -----------------------------------------------------------
    def _stage(self, kind: str, name: str, cached: bool, fn):
        """Run one pipeline stage under a ``plan.stage.<name>`` span.

        With the tracer disabled this is a plain call.  With it enabled,
        the stage result is blocked-on before the span closes -- jax
        dispatch is async, so without the sync every stage but the last
        would bill its device time to whoever touches the value next.
        """
        tr = obs_trace.get_tracer()
        if not tr.enabled:
            return fn()
        t0 = time.perf_counter()
        with tr.span("plan.stage." + name, kind=kind, cached=cached):
            out = _block_tree(fn())
        self._timings[name] = (time.perf_counter() - t0) * 1e3
        return out

    def execute(self, labels) -> jax.Array:
        """Run the staged pipeline for one labels vector.

        With the global tracer enabled, every stage runs under a
        ``plan.stage.*`` span (tagged with its prep-cache status) inside
        one ``plan.execute`` root span, and per-stage wall times are kept
        for :meth:`describe(timings=True) <describe>`.
        """
        tr = obs_trace.get_tracer()
        if not tr.enabled:
            return self._execute_stages(labels)
        self._timings.clear()
        p = self.prepared
        hits0, misses0 = p._hits, p._misses
        t0 = time.perf_counter()
        with tr.span("plan.execute", backend=self.backend,
                     n=p.num_nodes, e=p.num_edges, k=self.num_classes,
                     opts=self.opts.tag(), fused=self.fused) as root:
            z = _block_tree(self._execute_stages(labels))
            root.tag(cache_hits=p._hits - hits0,
                     cache_misses=p._misses - misses0)
        total_ms = (time.perf_counter() - t0) * 1e3
        self._timings["total_ms"] = total_ms
        reg = obs_metrics.get_registry()
        reg.counter("plan.executions").inc()
        reg.counter("plan.cache_hits").inc(p._hits - hits0)
        reg.counter("plan.cache_misses").inc(p._misses - misses0)
        reg.histogram("plan.execute_ms").observe(total_ms)
        return z

    def _execute_stages(self, labels) -> jax.Array:
        k, o, p = self.num_classes, self.opts, self.prepared
        if self.backend == "sparse_jax":
            eff = self._stage(
                "prep", "effective_edges",
                p.is_cached(("eff", o.diag_aug, o.laplacian)),
                lambda: p.effective_edges(o))
            # prep already applied: the scatter runs with bare options
            z = self._stage(
                "compute", "segment_scatter", False,
                lambda: gee_sparse_jax(eff, jnp.asarray(labels), k,
                                       GEEOptions()))
            if o.correlation:
                z = self._stage(
                    "epilogue", "row_l2_normalize", False,
                    lambda: epilogue.row_l2_normalize(z, impl=self.impl))
            return z
        if self.backend == "pallas":
            if self.fused:
                from repro.kernels.gee_fused import gee_fused_from_bucketed

                # base-graph packing: diag-aug folds in as deg+1 + the
                # in-kernel addend, so the augmented packing never builds
                bell = self._stage(
                    "prep", "bucketed_ell",
                    p.is_cached(("bucketed_ell", False)),
                    lambda: p.bucketed_ell(False))
                return self._stage(
                    "compute", "gee_spmm_fused", False,
                    lambda: gee_fused_from_bucketed(
                        bell, jnp.asarray(labels), k, o))
            from repro.kernels.ops import gee_pallas_from_bucketed

            bell = self._stage(
                "prep", "bucketed_ell",
                p.is_cached(("bucketed_ell", o.diag_aug)),
                lambda: p.bucketed_ell(o.diag_aug))
            z = self._stage(
                "compute", "gee_spmm", False,
                lambda: gee_pallas_from_bucketed(
                    bell, jnp.asarray(labels), k,
                    GEEOptions(laplacian=o.laplacian)))
            if o.correlation:      # epilogue honors this plan's impl choice
                z = self._stage(
                    "epilogue", "row_l2_normalize", False,
                    lambda: epilogue.row_l2_normalize(z, impl=self.impl))
            return z
        if self.backend == "chunked":
            from repro.core.chunked import gee_chunked

            chunk = self.chunk_edges
            manifest = self._stage(
                "prep", "chunk_manifest",
                p.is_cached(("chunked", _chunk_key(chunk))),
                lambda: p.chunked(chunk))
            return self._stage(
                "compute", "two_pass_stream", False,
                lambda: gee_chunked(manifest, labels, k, o, impl=self.impl,
                                    prefetch_windows=self.prefetch_windows))
        if self.backend == "streamed_sharded":
            from repro.core.fold import gee_streamed_sharded

            chunk = self.chunk_edges
            manifest = self._stage(
                "prep", "chunk_manifest",
                p.is_cached(("chunked", _chunk_key(chunk))),
                lambda: p.chunked(chunk))
            # default mesh over all local devices; rows come back [:N]
            return self._stage(
                "compute", "window_shard_fold", False,
                lambda: gee_streamed_sharded(
                    manifest, labels, k, o,
                    prefetch_windows=self.prefetch_windows))
        if self.backend == "dense_jax":
            return self._stage(
                "compute", "dense_matmul", False,
                lambda: gee_dense_jax(p.base, jnp.asarray(labels), k, o))
        src, dst, w = self._stage("prep", "host_arrays",
                                  p.is_cached(("host",)), p.host_arrays)
        y = np.asarray(labels)
        if self.backend == "scipy":
            return self._stage(
                "compute", "scipy", False,
                lambda: gee_scipy(src, dst, w, y, k, o,
                                  num_nodes=p.num_nodes))
        assert self.backend == "python_loop"
        return self._stage(
            "compute", "python_loop", False,
            lambda: gee_python_loop(src, dst, w, y, k, o,
                                    num_nodes=p.num_nodes))


# ---------------------------------------------------------------------------
# the many-settings fast path (ensemble / --compare sweeps)
# ---------------------------------------------------------------------------

def sweep_options(graph: PreparedGraph | EdgeList, labels, num_classes: int,
                  settings: Iterable[GEEOptions] = ALL_OPTION_SETTINGS, *,
                  backend: str = "sparse_jax", chunk_edges: int | None = None,
                  impl: str = "auto") -> Mapping[GEEOptions, jax.Array]:
    """Embed one graph under many option settings with all prep shared.

    Two sharing levels, both exact:

      * every setting reuses the ``PreparedGraph`` artifacts (symmetrized
        upload, self-loop augmentation, Laplacian fold, packing);
      * correlation is a pure row postprocess, so settings that differ
        only in it share the same scatter pass -- the 8 canonical
        settings cost 4 scatters + 4 row normalizations.

    Returns ``{opts: Z}`` in the order given.
    """
    prepared = PreparedGraph.wrap(graph)
    raw: Dict[Tuple[bool, bool], jax.Array] = {}
    out: Dict[GEEOptions, jax.Array] = {}
    for opts in settings:
        key = (bool(opts.laplacian), bool(opts.diag_aug))
        if key not in raw:
            base = GEEOptions(laplacian=opts.laplacian,
                              diag_aug=opts.diag_aug)
            raw[key] = GEEPlan.build(
                prepared, num_classes, base, backend=backend,
                chunk_edges=chunk_edges, impl=impl).execute(labels)
        z = raw[key]
        if opts.correlation:
            z = epilogue.row_l2_normalize(jnp.asarray(z), impl=impl)
        out[opts] = z
    return out


Graph = Union[PreparedGraph, EdgeList]

__all__ = ["PreparedGraph", "GEEPlan", "PlanStage", "select_backend",
           "select_fused", "sweep_options", "estimate_working_set_bytes",
           "memory_budget_bytes", "KNOWN_BACKENDS", "ENV_MEMORY_BUDGET",
           "DEFAULT_MEMORY_BUDGET", "PALLAS_MAX_CLASSES"]
