"""Plan/executor layer: PreparedGraph memoization, GEEPlan equivalence
across every backend, the cost-model auto selection, the shared epilogue
numerics, and the unified autotune registry."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import epilogue
from repro.core.gee import (ALL_OPTION_SETTINGS, GEEOptions, gee,
                            gee_sparse_jax)
from repro.core.plan import (GEEPlan, PreparedGraph, estimate_working_set_bytes,
                             select_backend, sweep_options)
from repro.graph.containers import (add_self_loops, edge_list_from_numpy,
                                    symmetrize)
from repro.kernels.autotune import (AutotuneRegistry, REGISTRY, ceil_to,
                                    pow2_at_least, pow2_bucket)

OPTS_ALL = GEEOptions(laplacian=True, diag_aug=True, correlation=True)


def _random_edges(n=60, e=240, seed=0, weighted=True):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = (src + 1 + rng.integers(0, n - 1, e)) % n
    w = (rng.random(e).astype(np.float32) + 0.1) if weighted else None
    return symmetrize(edge_list_from_numpy(src, dst, w, n))


def _random_labels(n=60, k=4, seed=0):
    return np.random.default_rng(seed).integers(-1, k, n).astype(np.int32)


# ---------------------------------------------------------------------------
# PreparedGraph: cached artifacts == fresh counterparts
# ---------------------------------------------------------------------------

def test_prepared_artifacts_match_fresh():
    edges = _random_edges()
    prep = PreparedGraph.wrap(edges)

    aug = prep.with_self_loops()
    fresh_aug = add_self_loops(edges)
    for f in ("src", "dst", "weight"):
        np.testing.assert_array_equal(np.asarray(getattr(aug, f)),
                                      np.asarray(getattr(fresh_aug, f)))
    assert aug.num_edges == fresh_aug.num_edges

    for diag in (False, True):
        e = fresh_aug if diag else edges
        deg = np.asarray(prep.degrees(diag))
        ref = np.zeros(edges.num_nodes, np.float32)
        np.add.at(ref, np.asarray(e.src), np.asarray(e.weight))
        np.testing.assert_allclose(deg, ref, rtol=1e-5, atol=1e-5)

    # effective edges: second call returns the identical cached object
    eff1 = prep.effective_edges(OPTS_ALL)
    eff2 = prep.effective_edges(GEEOptions(laplacian=True, diag_aug=True))
    assert eff1 is eff2            # correlation never invalidates prep
    info = prep.cache_info()
    assert info["hits"] >= 1


def test_prepared_effective_edges_numerics():
    """Scatter over cached effective edges == the fused one-jit path."""
    edges = _random_edges(seed=3)
    labels = _random_labels(seed=3)
    prep = PreparedGraph.wrap(edges)
    for opts in ALL_OPTION_SETTINGS:
        eff = prep.effective_edges(opts)
        z_prep = np.asarray(gee_sparse_jax(
            eff, jnp.asarray(labels), 4,
            GEEOptions(correlation=opts.correlation)))
        z_fused = np.asarray(gee_sparse_jax(edges, jnp.asarray(labels), 4,
                                            opts))
        np.testing.assert_allclose(z_prep, z_fused, atol=1e-6,
                                   err_msg=opts.tag())


def test_prepared_from_arrays_symmetrizes_once():
    src = np.array([0, 1, 2])
    dst = np.array([1, 2, 0])
    prep = PreparedGraph.from_arrays(src, dst, None, num_nodes=3)
    assert prep.num_edges == 6          # symmetrized
    direct = PreparedGraph.from_arrays(src, dst, None, num_nodes=3,
                                       undirected=False)
    assert direct.num_edges == 3


def test_prepared_wrap_idempotent_and_typed():
    edges = _random_edges()
    prep = PreparedGraph.wrap(edges)
    assert PreparedGraph.wrap(prep) is prep
    with pytest.raises(TypeError):
        PreparedGraph(prep)
    with pytest.raises(TypeError):
        PreparedGraph("not edges")


# ---------------------------------------------------------------------------
# hypothesis property: every cached artifact equals its fresh counterpart
# ---------------------------------------------------------------------------

def _check_cached_equals_fresh(edges, lap, diag):
    """PreparedGraph artifacts must be exactly what a cold path derives."""
    from repro.graph.ell import edges_to_bucketed_ell
    from repro.graph.io import ChunkedEdgeList

    prep = PreparedGraph.wrap(edges)
    opts = GEEOptions(laplacian=lap, diag_aug=diag)

    eff_cold_edges = add_self_loops(edges) if diag else edges
    if lap:
        from repro.core.gee import laplacian_edge_weights
        w_cold = np.asarray(laplacian_edge_weights(eff_cold_edges))
    else:
        w_cold = np.asarray(eff_cold_edges.weight)
    eff = prep.effective_edges(opts)
    eff_again = prep.effective_edges(opts)
    assert eff is eff_again
    np.testing.assert_allclose(np.asarray(eff.weight), w_cold, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(eff.src),
                                  np.asarray(eff_cold_edges.src))

    bell = prep.bucketed_ell(diag)
    bell_cold = edges_to_bucketed_ell(add_self_loops(edges) if diag
                                      else edges)
    assert len(bell.buckets) == len(bell_cold.buckets)
    for b, bc in zip(bell.buckets, bell_cold.buckets):
        np.testing.assert_array_equal(np.asarray(b.cols),
                                      np.asarray(bc.cols))
        np.testing.assert_allclose(np.asarray(b.vals), np.asarray(bc.vals),
                                   atol=0)

    ch = prep.chunked(16)
    ch_cold = ChunkedEdgeList.from_edge_list(edges, 16)
    np.testing.assert_array_equal(ch.src, ch_cold.src)
    np.testing.assert_array_equal(ch.weight, ch_cold.weight)
    assert prep.chunked(16) is ch      # memoized per window size


@pytest.mark.parametrize("lap,diag", [(False, False), (True, True)])
def test_cached_equals_fresh_deterministic(lap, diag):
    """Always-on twin of the hypothesis property below."""
    _check_cached_equals_fresh(_random_edges(n=30, e=80, seed=5), lap, diag)


try:                       # optional dep: only the property test needs it
    from hypothesis import given, settings, strategies as st

    @st.composite
    def small_graph(draw):
        n = draw(st.integers(2, 30))
        e = draw(st.integers(1, 80))
        src = draw(st.lists(st.integers(0, n - 1), min_size=e, max_size=e))
        dst = draw(st.lists(st.integers(0, n - 1), min_size=e, max_size=e))
        w = draw(st.lists(st.floats(0.1, 5.0, allow_nan=False), min_size=e,
                          max_size=e))
        return symmetrize(edge_list_from_numpy(
            np.array(src, np.int32), np.array(dst, np.int32),
            np.array(w, np.float32), n))

    @settings(max_examples=25, deadline=None)
    @given(small_graph(), st.booleans(), st.booleans())
    def test_property_cached_equals_fresh(edges, lap, diag):
        _check_cached_equals_fresh(edges, lap, diag)

except ImportError:        # pragma: no cover - minimal installs
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_cached_equals_fresh():
        pass


# ---------------------------------------------------------------------------
# GEEPlan: every backend numerically equivalent through the plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opts", ALL_OPTION_SETTINGS,
                         ids=[o.tag() for o in ALL_OPTION_SETTINGS])
def test_all_backends_equivalent_through_plan(opts):
    edges = _random_edges(n=80, e=400, seed=7)
    labels = _random_labels(n=80, seed=7)
    prep = PreparedGraph.wrap(edges)
    ref = np.asarray(GEEPlan.build(prep, 4, opts,
                                   backend="dense_jax").execute(labels))
    for backend in ("sparse_jax", "pallas", "chunked", "scipy",
                    "python_loop"):
        z = np.asarray(GEEPlan.build(prep, 4, opts,
                                     backend=backend).execute(labels))
        assert np.abs(z - ref).max() <= 1e-5, (backend, opts.tag())


def test_plan_stages_and_describe():
    prep = PreparedGraph.wrap(_random_edges())
    plan = GEEPlan.build(prep, 4, OPTS_ALL, backend="sparse_jax")
    kinds = [s.kind for s in plan.stages]
    assert kinds == ["prep", "compute", "epilogue"]
    assert not plan.stages[0].cached
    plan.execute(_random_labels())
    # same plan after execution: the prep artifact is now resident
    assert GEEPlan.build(prep, 4, OPTS_ALL).stages[0].cached
    assert "segment_scatter" in plan.describe()


def test_plan_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown backend"):
        GEEPlan.build(_random_edges(), 4, backend="nope")


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_select_backend_cost_model():
    edges = _random_edges()
    # tiny budget -> out-of-core streaming (pin num_devices: the default
    # asks jax, and the ambient device count is the suite's, not ours)
    assert select_backend(edges, 4, budget_bytes=16,
                          num_devices=1) == "chunked"
    # ample budget off-TPU -> the segment-sum default
    assert select_backend(edges, 4, device="cpu",
                          budget_bytes=1 << 40) == "sparse_jax"
    # TPU with lane-sized K -> the kernel; huge K -> back to segment-sum
    assert select_backend(edges, 4, device="tpu",
                          budget_bytes=1 << 40) == "pallas"
    assert select_backend(edges, 100_000, device="tpu",
                          budget_bytes=1 << 40) == "sparse_jax"
    assert estimate_working_set_bytes(edges, 4) > 0


def test_select_backend_streams_across_devices_over_budget():
    edges = _random_edges()
    # over budget + >1 device: split every window across the mesh
    assert select_backend(edges, 4, budget_bytes=16,
                          num_devices=4) == "streamed_sharded"
    # a single device still streams through the chunked fold
    assert select_backend(edges, 4, budget_bytes=16,
                          num_devices=1) == "chunked"


def test_pallas_estimate_sees_ell_padding_blowup():
    """Regression (cost model): on a skewed degree distribution the
    bucketed ELL packing costs far more than the raw edge count -- the
    flat estimate used to route hub graphs to ``pallas`` that could not
    fit after packing."""
    n = 2000                               # star: hub 0 <-> every other node
    hub = np.zeros(n - 1, np.int64)
    spokes = np.arange(1, n, dtype=np.int64)
    edges = edge_list_from_numpy(np.concatenate([hub, spokes]),
                                 np.concatenate([spokes, hub]), None, n)
    flat = estimate_working_set_bytes(edges, 4)
    packed = estimate_working_set_bytes(edges, 4, backend="pallas")
    # hub row pads to pow2(~n) slots; the tail pads to the 8-wide bucket
    assert packed > 1.5 * flat
    # budget between the two: the kernel must NOT be selected on TPU...
    budget = (flat + packed) // 2
    assert flat < budget < packed
    assert select_backend(edges, 4, device="tpu",
                          budget_bytes=budget) == "sparse_jax"
    # ...but a budget that covers the packed set still picks it
    assert select_backend(edges, 4, device="tpu",
                          budget_bytes=1 << 40) == "pallas"
    # PreparedGraph memoizes the O(E) slot count under ("ell_slots",)
    prep = PreparedGraph.wrap(edges)
    assert estimate_working_set_bytes(prep, 4, backend="pallas") \
        == estimate_working_set_bytes(prep, 4, backend="pallas")
    assert prep.is_cached(("ell_slots",))


def test_auto_routes_to_chunked_by_budget(monkeypatch):
    from repro.core.plan import ENV_MEMORY_BUDGET

    monkeypatch.setenv(ENV_MEMORY_BUDGET, "64")
    edges = _random_edges()
    plan = GEEPlan.build(edges, 4, OPTS_ALL, backend="auto")
    assert plan.backend == "chunked"
    z = np.asarray(plan.execute(_random_labels()))
    ref = np.asarray(gee(edges, _random_labels(), 4, OPTS_ALL,
                         backend="sparse_jax"))
    np.testing.assert_allclose(z, ref, atol=1e-5)


# ---------------------------------------------------------------------------
# satellite: gee(backend="chunked") reuses the cached chunk manifest
# ---------------------------------------------------------------------------

def test_chunked_backend_no_rebuild(monkeypatch):
    from repro.graph import io as gio

    calls = {"n": 0}
    real = gio.ChunkedEdgeList.from_edge_list    # staticmethod -> function

    def counting(edges, chunk_edges=gio.DEFAULT_CHUNK_EDGES):
        calls["n"] += 1
        return real(edges, chunk_edges)

    monkeypatch.setattr(gio.ChunkedEdgeList, "from_edge_list",
                        staticmethod(counting))
    edges = _random_edges()
    labels = _random_labels()
    prep = PreparedGraph.wrap(edges)
    z1 = gee(prep, labels, 4, OPTS_ALL, backend="chunked")
    z2 = gee(prep, labels, 4, GEEOptions(), backend="chunked")
    assert calls["n"] == 1, "second chunked fit rebuilt the manifest"
    assert prep.is_cached(("chunked", gio.DEFAULT_CHUNK_EDGES))
    del z1, z2


def test_embedder_chunked_backend_no_rebuild(monkeypatch):
    from repro.core.api import GEEEmbedder
    from repro.graph import io as gio

    calls = {"n": 0}
    real = gio.ChunkedEdgeList.from_edge_list    # staticmethod -> function

    def counting(edges, chunk_edges=gio.DEFAULT_CHUNK_EDGES):
        calls["n"] += 1
        return real(edges, chunk_edges)

    monkeypatch.setattr(gio.ChunkedEdgeList, "from_edge_list",
                        staticmethod(counting))
    edges = _random_edges()
    labels = _random_labels()
    emb = GEEEmbedder(num_classes=4, backend="chunked", chunk_edges=64)
    emb.fit(edges, labels)
    emb.transform()
    emb._z = None                  # force a recompute on the same fit
    emb.transform()
    assert calls["n"] == 1, "recompute rebuilt the chunk manifest"


# ---------------------------------------------------------------------------
# sweep_options: the 8-setting fast path is exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["sparse_jax", "chunked"])
def test_sweep_options_matches_per_call(backend):
    edges = _random_edges(n=50, e=200, seed=9)
    labels = _random_labels(n=50, seed=9)
    zs = sweep_options(edges, labels, 4, backend=backend)
    assert len(zs) == len(ALL_OPTION_SETTINGS)
    for opts, z in zs.items():
        ref = np.asarray(gee(edges, labels, 4, opts, backend="sparse_jax"))
        assert np.abs(np.asarray(z) - ref).max() <= 1e-5, opts.tag()


def test_embedder_consumes_prepared():
    from repro.core.api import GEEEmbedder

    edges = _random_edges()
    labels = _random_labels()
    emb1 = GEEEmbedder(num_classes=4).fit(edges, labels)
    z1 = np.asarray(emb1.transform())
    # a second embedder reuses the first one's prep artifacts
    emb2 = GEEEmbedder(num_classes=4,
                       options=GEEOptions(laplacian=True)).fit(
        emb1.prepared, labels)
    assert emb2.prepared is emb1.prepared
    z2 = np.asarray(emb2.transform())
    ref = np.asarray(gee(edges, labels, 4, GEEOptions(laplacian=True)))
    np.testing.assert_allclose(z2, ref, atol=1e-6)
    assert z1.shape == z2.shape


# ---------------------------------------------------------------------------
# shared epilogue numerics
# ---------------------------------------------------------------------------

def test_epilogue_impls_agree():
    rng = np.random.default_rng(0)
    z = rng.normal(size=(37, 5)).astype(np.float32)
    z[5] = 0.0                                   # zero row stays zero
    a = np.asarray(epilogue.row_l2_normalize(jnp.asarray(z), impl="jnp"))
    b = np.asarray(epilogue.row_l2_normalize(jnp.asarray(z), impl="pallas",
                                             interpret=True))
    c = epilogue.row_l2_normalize_np(z)
    np.testing.assert_allclose(a, b, atol=1e-6)
    np.testing.assert_allclose(a, c.astype(np.float32), atol=1e-6)
    np.testing.assert_array_equal(a[5], np.zeros(5, np.float32))
    np.testing.assert_allclose(np.linalg.norm(a[0]), 1.0, atol=1e-6)
    with pytest.raises(ValueError, match="unknown impl"):
        epilogue.row_l2_normalize(jnp.asarray(z), impl="bogus")


def test_epilogue_degree_inversion_twins():
    deg = np.array([0.0, 1.0, 4.0, 1e-35], np.float64)
    a = np.asarray(epilogue.inv_sqrt_degrees(jnp.asarray(deg,
                                                         jnp.float32)))
    b = epilogue.inv_sqrt_degrees_np(deg)
    np.testing.assert_allclose(a[:3], b[:3].astype(np.float32), rtol=1e-6)
    assert a[0] == 0.0 and b[0] == 0.0


# ---------------------------------------------------------------------------
# unified autotune registry
# ---------------------------------------------------------------------------

def test_autotune_helpers():
    assert ceil_to(1, 8) == 8 and ceil_to(8, 8) == 8 and ceil_to(9, 8) == 16
    assert pow2_at_least(0) == 1 and pow2_at_least(5) == 8
    assert pow2_bucket(3, 100, 1) == (4, 128, 1)


def test_registry_resolution_order_and_roundtrip(tmp_path):
    reg = AutotuneRegistry()
    reg.register("k", table={(8, 8): (1, 1)},
                 fallback=lambda key: (key[0], key[1]))
    assert reg.lookup("k", (8, 8)) == (1, 1)        # table
    assert reg.lookup("k", (16, 8)) == (16, 8)      # formula
    reg.record("k", (16, 8), (2, 2))                # measurement wins
    assert reg.lookup("k", (16, 8)) == (2, 2)
    path = str(tmp_path / "tune.json")
    assert reg.save(path) == path

    reg2 = AutotuneRegistry()
    reg2.register("k", fallback=lambda key: (0, 0))
    assert reg2.load(path) == 1
    assert reg2.lookup("k", (16, 8)) == (2, 2)      # persisted entry
    assert reg2.load(str(tmp_path / "absent.json")) == 0
    reg2.clear("k")
    assert reg2.lookup("k", (16, 8)) == (0, 0)
    with pytest.raises(KeyError):
        reg.lookup("unregistered", (1,))


def test_registry_env_persistence(tmp_path, monkeypatch):
    from repro.kernels.autotune import ENV_CACHE_PATH

    path = str(tmp_path / "env_tune.json")
    monkeypatch.setenv(ENV_CACHE_PATH, path)
    reg = AutotuneRegistry()
    reg.register("k", fallback=lambda key: (3,))
    reg.record("k", (4,), (9,))
    assert reg.save() == path                       # env default path
    reg2 = AutotuneRegistry()
    reg2.register("k", fallback=lambda key: (3,))
    assert reg2.lookup("k", (4,)) == (9,)           # lazy env load


def test_shared_registry_serves_kernels():
    """The real kernels resolve through the one shared REGISTRY."""
    from repro.kernels.gee_spmm import choose_block_sizes
    from repro.kernels.topk_score import (choose_gathered_blocks,
                                          choose_pairwise_blocks)

    assert {"gee_spmm", "topk_pairwise",
            "topk_gathered"} <= set(REGISTRY.kernels())
    br, bd, ds = choose_block_sizes(1000, 100, 4)
    assert br % 8 == 0 and bd >= 8 and 1 <= ds <= bd
    bq, bm = choose_pairwise_blocks(100, 1000, 4)
    assert bq >= 8 and bm >= 8
    bq, bm = choose_gathered_blocks(100, 500, 4)
    assert bq >= 8 and bm >= 8


def test_deprecated_helper_aliases_still_importable():
    from repro.core.gee import select_backend as old_select
    from repro.kernels.gee_spmm import (_ceil_to as c1,
                                        _pow2_at_least as p1)
    from repro.kernels.row_norm import _ceil_to as c2
    from repro.kernels.topk_score import (_ceil_to as c3,
                                          _pow2_at_least as p2)

    assert c1(9, 8) == c2(9, 8) == c3(9, 8) == 16
    assert p1(5) == p2(5) == 8
    assert old_select(_random_edges(), 4) in ("sparse_jax", "pallas",
                                              "chunked")
