"""IO-layer edge cases: the on-disk formats, the chunked reader, and the
converters (repro/graph/io.py).  The tentpole contract under test: every
format round-trips exactly, chunk iteration is shape-stable no matter how
chunk_edges divides E, and SNAP quirks (comments, 1-indexing) parse."""

import os

import numpy as np
import pytest

from repro.graph.io import (ChunkedEdgeList, BinaryEdgeWriter, convert,
                            labels_path, load_labels, open_edge_list,
                            read_binary_header, save_edge_list, save_labels,
                            scan_text, write_binary)


def _random_chunked(rng, n=120, e=700, undirected=True, chunk=97):
    src = rng.integers(0, n, e).astype(np.int32)
    dst = (src + 1 + rng.integers(0, n - 1, e)).astype(np.int32) % n
    w = (rng.random(e) + 0.1).astype(np.float32)
    return ChunkedEdgeList(src=src, dst=dst, weight=w, num_nodes=n,
                           chunk_edges=chunk, undirected=undirected)


# ---------------------------------------------------------------------------
# SNAP text parsing quirks
# ---------------------------------------------------------------------------

def test_text_comments_headers_and_blank_lines(tmp_path):
    p = str(tmp_path / "snap.txt")
    with open(p, "w") as f:
        f.write("# Directed graph: example\n"
                "% matrix-market style comment\n"
                "// c-style comment\n"
                "\n"
                "# FromNodeId\tToNodeId\n"
                "0\t1\n"
                "1 2\n"
                "\n"
                "2 0\n")
    ch = open_edge_list(p, chunk_edges=10)
    assert ch.num_edges == 3
    assert ch.num_nodes == 3
    np.testing.assert_array_equal(np.asarray(ch.src), [0, 1, 2])
    np.testing.assert_array_equal(np.asarray(ch.dst), [1, 2, 0])


def test_text_one_indexed_nodes(tmp_path):
    p = str(tmp_path / "one_indexed.txt")
    with open(p, "w") as f:
        f.write("1 2\n2 3\n3 1\n")
    ch = open_edge_list(p, index_base=1, chunk_edges=10)
    assert ch.num_nodes == 3
    np.testing.assert_array_equal(np.asarray(ch.src), [0, 1, 2])
    # a 0-indexed read of the same file must not reuse the index_base=1
    # sidecar: it sees node ids up to 3
    ch0 = open_edge_list(p, chunk_edges=10)
    assert ch0.num_nodes == 4


def test_num_nodes_override_does_not_poison_sidecar_cache(tmp_path):
    p = str(tmp_path / "iso.txt")
    with open(p, "w") as f:
        f.write("0 1\n1 2\n")
    # override applies at open time (isolated trailing nodes kept) ...
    assert open_edge_list(p, num_nodes=10).num_nodes == 10
    # ... but is not baked into the cached sidecar
    assert open_edge_list(p).num_nodes == 3
    assert open_edge_list(p, num_nodes=7).num_nodes == 7
    # the same override works on binary sources
    g = str(tmp_path / "iso.geeb")
    write_binary(g, np.array([0], np.int32), np.array([1], np.int32),
                 None, num_nodes=2)
    assert open_edge_list(g, num_nodes=5).num_nodes == 5


def test_text_negative_after_index_base_raises(tmp_path):
    p = str(tmp_path / "bad.txt")
    with open(p, "w") as f:
        f.write("0 1\n")
    with pytest.raises(ValueError, match="negative node id"):
        scan_text(p, index_base=1)


def test_text_weighted_column_and_scan(tmp_path):
    p = str(tmp_path / "weighted.tsv")
    with open(p, "w") as f:
        f.write("0\t1\t0.5\n1\t2\t2.25\n")
    e, mx = scan_text(p)
    assert (e, mx) == (2, 2)
    ch = open_edge_list(p, chunk_edges=10)
    np.testing.assert_allclose(np.asarray(ch.weight), [0.5, 2.25])


def test_text_sidecar_cache_refreshes_on_newer_text(tmp_path):
    p = str(tmp_path / "cached.txt")
    with open(p, "w") as f:
        f.write("0 1\n")
    assert open_edge_list(p).num_edges == 1
    assert os.path.exists(p + ".geeb")
    with open(p, "w") as f:
        f.write("0 1\n1 2\n")
    os.utime(p, (os.path.getmtime(p + ".geeb") + 5,) * 2)
    assert open_edge_list(p).num_edges == 2


# ---------------------------------------------------------------------------
# chunk iteration: shapes and tails
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,chunk", [
    (700, 97),      # does not divide E: ragged tail chunk
    (700, 100),     # divides E exactly: no tail padding
    (700, 7000),    # single chunk larger than E: clamped, no waste
    (1, 64),        # single edge
])
def test_chunks_are_shape_stable_and_cover_all_edges(e, chunk):
    rng = np.random.default_rng(0)
    ch = _random_chunked(rng, e=e, chunk=chunk)
    chunks = list(ch.chunks())
    assert len(chunks) == ch.num_chunks
    eff = ch.effective_chunk_edges
    assert eff <= max(e, 1)
    # stable shapes: every chunk padded to the same width
    assert {c.padded_size for c in chunks} == {eff}
    assert sum(c.num_edges for c in chunks) == e
    # padding slots carry weight 0 (exact no-ops)
    for c in chunks:
        np.testing.assert_array_equal(
            np.asarray(c.weight)[c.num_edges:], 0.0)
    # concatenated valid prefixes reproduce the stored arrays
    src_cat = np.concatenate([np.asarray(c.src)[:c.num_edges]
                              for c in chunks])
    np.testing.assert_array_equal(src_cat, np.asarray(ch.src))


def test_empty_graph_yields_one_padded_noop_chunk(tmp_path):
    p = str(tmp_path / "empty.geeb")
    write_binary(p, np.empty(0, np.int32), np.empty(0, np.int32), None,
                 num_nodes=5)
    ch = open_edge_list(p, chunk_edges=8)
    assert (ch.num_edges, ch.num_chunks) == (0, 1)
    (chunk,) = list(ch.chunks())
    assert chunk.num_edges == 0 and chunk.padded_size == 1
    np.testing.assert_array_equal(np.asarray(chunk.weight), 0.0)


def test_all_padding_tail_window_skipped():
    """Regression (streamed fold): a stored tail whose weights are all
    zero used to reach consumers as an all-padding window.  ``chunks()``
    must skip it, and every yielded window's valid prefix must contribute
    at least one real edge."""
    e, chunk = 10, 4                      # chunk does not divide E
    src = np.arange(e, dtype=np.int32) % 5
    dst = (src + 1) % 5
    w = np.ones(e, np.float32)
    w[8:] = 0.0                           # final ragged window: all zeros
    ch = ChunkedEdgeList(src=src, dst=dst, weight=w, num_nodes=5,
                         chunk_edges=chunk)
    windows = list(ch.chunks())
    assert len(windows) == 2              # third (all-padding) one skipped
    assert len(windows) < ch.num_chunks   # num_chunks is an upper bound
    for win in windows:
        assert np.any(np.asarray(win.weight)), "all-padding window yielded"
    # the raw storage iterator still sees every stored window (save paths)
    assert len(list(ch._raw_windows())) == ch.num_chunks == 3


def test_from_edge_list_drops_zero_weight_entries():
    from repro.graph.containers import edge_list_from_numpy

    edges = edge_list_from_numpy(np.array([0, 1, 2, 3]),
                                 np.array([1, 2, 3, 0]),
                                 np.array([1.0, 0.0, 2.0, 0.0]), 4)
    ch = ChunkedEdgeList.from_edge_list(edges, chunk_edges=64)
    assert ch.num_edges == 2              # exact no-ops never stored
    np.testing.assert_array_equal(np.asarray(ch.weight), [1.0, 2.0])
    # an all-zero-weight graph degrades to the empty-graph contract:
    # one all-padding no-op window, nothing yielded is malformed
    empty = ChunkedEdgeList.from_edge_list(
        edge_list_from_numpy(np.array([0]), np.array([1]),
                             np.array([0.0]), 2), chunk_edges=8)
    assert empty.num_edges == 0
    (win,) = list(empty.chunks())
    assert win.num_edges == 0 and win.padded_size == 1


def test_zero_weight_tail_round_trips_through_save(tmp_path):
    """save_edge_list streams via the *raw* windows: stored zero-weight
    entries must survive a .geeb round-trip byte-exact (the writer
    enforces the declared edge count)."""
    e = 10
    src = np.arange(e, dtype=np.int32) % 5
    dst = (src + 1) % 5
    w = np.ones(e, np.float32)
    w[8:] = 0.0
    ch = ChunkedEdgeList(src=src, dst=dst, weight=w, num_nodes=5,
                         chunk_edges=4)
    p = str(tmp_path / "tail.geeb")
    save_edge_list(p, ch)
    back = open_edge_list(p, chunk_edges=4)
    assert back.num_edges == e
    np.testing.assert_array_equal(np.asarray(back.weight), w)


def test_to_edge_list_symmetrizes_undirected_storage():
    rng = np.random.default_rng(1)
    ch = _random_chunked(rng, e=50, undirected=True)
    edges = ch.to_edge_list()
    assert edges.num_edges == 100          # no self loops in the sampler
    directed = _random_chunked(rng, e=50, undirected=False)
    assert directed.to_edge_list().num_edges == 50


# ---------------------------------------------------------------------------
# formats: header, round-trips, converters
# ---------------------------------------------------------------------------

def test_binary_header_and_flags(tmp_path):
    p = str(tmp_path / "h.geeb")
    write_binary(p, np.array([0, 1], np.int32), np.array([1, 2], np.int32),
                 np.array([1.0, 2.0], np.float32), num_nodes=7,
                 undirected=True)
    assert read_binary_header(p) == (7, 2, True)
    with pytest.raises(ValueError, match="not a .geeb"):
        bad = str(tmp_path / "bad.geeb")
        with open(bad, "wb") as f:
            f.write(b"\0" * 64)
        read_binary_header(bad)


def test_binary_writer_enforces_declared_edge_count(tmp_path):
    p = str(tmp_path / "short.geeb")
    w = BinaryEdgeWriter(p, num_nodes=4, num_edges=3)
    w.append(np.array([0], np.int32), np.array([1], np.int32))
    with pytest.raises(ValueError, match="wrote 1 of 3"):
        w.close()
    w2 = BinaryEdgeWriter(p, num_nodes=4, num_edges=1)
    with pytest.raises(ValueError, match="into a file sized for"):
        w2.append(np.array([0, 1], np.int32), np.array([1, 2], np.int32))


@pytest.mark.parametrize("fmt", ["geeb", "npz", "txt"])
def test_round_trip_each_format(tmp_path, fmt):
    rng = np.random.default_rng(2)
    ch = _random_chunked(rng, e=230, chunk=64, undirected=True)
    p = str(tmp_path / f"rt.{fmt}")
    save_edge_list(p, ch)
    back = open_edge_list(p, chunk_edges=33)
    assert back.num_nodes == ch.num_nodes
    assert back.num_edges == ch.num_edges
    assert back.undirected == ch.undirected
    np.testing.assert_array_equal(np.asarray(back.src), np.asarray(ch.src))
    np.testing.assert_array_equal(np.asarray(back.dst), np.asarray(ch.dst))
    np.testing.assert_array_equal(np.asarray(back.weight),
                                  np.asarray(ch.weight))


def test_convert_chain_across_all_three_formats(tmp_path):
    rng = np.random.default_rng(3)
    ch = _random_chunked(rng, e=150, chunk=41, undirected=False)
    p0 = str(tmp_path / "a.geeb")
    save_edge_list(p0, ch)
    p1 = convert(p0, str(tmp_path / "b.npz"), chunk_edges=37)
    p2 = convert(p1, str(tmp_path / "c.txt"), chunk_edges=37)
    p3 = convert(p2, str(tmp_path / "d.geeb"), chunk_edges=37)
    end = open_edge_list(p3)
    assert end.num_nodes == ch.num_nodes
    assert end.undirected == ch.undirected
    np.testing.assert_array_equal(np.asarray(end.src), np.asarray(ch.src))
    np.testing.assert_array_equal(np.asarray(end.weight),
                                  np.asarray(ch.weight))


def test_unknown_suffix_rejected(tmp_path):
    with pytest.raises(ValueError, match="unsupported edge-file suffix"):
        open_edge_list(str(tmp_path / "graph.parquet"))


# ---------------------------------------------------------------------------
# labels sidecar
# ---------------------------------------------------------------------------

def test_labels_sidecar_round_trip(tmp_path):
    p = str(tmp_path / "g.geeb")
    write_binary(p, np.array([0], np.int32), np.array([1], np.int32),
                 None, num_nodes=2)
    assert load_labels(p) is None
    save_labels(p, np.array([1, -1], np.int64))
    got = load_labels(p)
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, [1, -1])
    assert labels_path(p) == p + ".labels.npy"
