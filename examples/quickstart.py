"""Quickstart: the paper's pipeline in 40 lines.

Samples an SBM graph (the paper's simulation setup), embeds it with sparse
GEE (all three options on), classifies vertices from the embedding, and
runs unsupervised clustering -- then cross-checks every backend, and
finishes with the out-of-core path: a graph written to disk and embedded
in bounded memory without ever materializing the edge list.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.core.api import GEEEmbedder
from repro.core.ensemble import adjusted_rand_index, gee_cluster
from repro.core.gee import GEEOptions
from repro.graph.datasets import DatasetSpec, synth_to_disk
from repro.graph.sbm import sample_sbm


def main():
    # the paper's SBM: 3 classes, priors [.2, .3, .5], p_in=.13, p_out=.10
    graph = sample_sbm(num_nodes=2000, seed=0)
    print(f"SBM: N={graph.edges.num_nodes}, "
          f"E={graph.edges.num_edges // 2} undirected edges")

    opts = GEEOptions(laplacian=True, diag_aug=True, correlation=True)

    # 1. embed (production sparse path)
    emb = GEEEmbedder(num_classes=graph.num_classes, options=opts)
    z = np.asarray(emb.fit_transform(graph.edges, graph.labels))
    print(f"embedding Z: {z.shape}, rows unit-norm: "
          f"{np.allclose(np.linalg.norm(z, axis=1)[z.any(1)], 1.0, atol=1e-4)}")

    # 2. vertex classification from the embedding
    acc = float((np.asarray(emb.predict()) == graph.labels).mean())
    print(f"nearest-class-mean accuracy: {acc:.3f}")

    # 3. unsupervised clustering (encoder ensemble).  The paper's SBM
    # (0.13 vs 0.10) sits near the detectability threshold at this size,
    # so the clustering demo uses a better-separated SBM.
    graph2 = sample_sbm(num_nodes=2000, p_within=0.18, p_between=0.04,
                        seed=1)
    res = gee_cluster(graph2.edges, graph2.num_classes, replicates=3,
                      seed=0)
    ari = adjusted_rand_index(np.asarray(res.labels), graph2.labels)
    print(f"clustering ARI (no labels used, separated SBM): {ari:.3f}")

    # 4. every backend agrees (the paper's core claim: the speedup is free)
    for backend in ("dense_jax", "scipy", "pallas", "chunked"):
        z2 = np.asarray(GEEEmbedder(num_classes=graph.num_classes,
                                    options=opts, backend=backend)
                        .fit_transform(graph.edges, graph.labels))
        print(f"max |Z - Z_{backend}| = {np.abs(z - z2).max():.2e}")

    # 5. out-of-core: stream a generated-on-disk graph in 64k-edge chunks.
    # synth_to_disk never holds the edge list in memory, and neither does
    # fit_transform_file -- peak usage is O(chunk_edges + N*K) however
    # large the file grows (labels ride along in a .labels.npy sidecar).
    path = os.path.join(tempfile.mkdtemp(), "disk_graph.geeb")
    spec = DatasetSpec("disk-demo", num_nodes=50_000, num_edges=500_000,
                       num_classes=6)
    synth_to_disk(spec, path, seed=0)
    emb = GEEEmbedder(num_classes=spec.num_classes, options=opts,
                      chunk_edges=1 << 16)
    z_disk = np.asarray(emb.fit_transform_file(path))
    acc_disk = float((np.asarray(emb.predict())
                      == np.load(path + ".labels.npy")).mean())
    print(f"out-of-core: {spec.num_edges} edges from {path}, "
          f"Z {z_disk.shape}, file {os.path.getsize(path)/1e6:.1f} MB, "
          f"acc {acc_disk:.3f}")


if __name__ == "__main__":
    main()
