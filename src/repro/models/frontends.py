"""Modality frontends (STUBS per the assignment).

The [vlm] and [audio] archs specify the transformer backbone only; the
modality encoder is replaced by ``input_specs()``-provided *precomputed*
embeddings:

  patch  (qwen2-vl):  batch["patches"] [B, n_patch, frontend_dim] are
         precomputed vision-patch embeddings, linearly projected and
         prepended to the text-token embeddings; M-RoPE gets a (t, h, w)
         position triple per slot (grid positions for patches, running t
         for text).
  frame  (hubert):    batch["frames"] [B, S, frontend_dim] are precomputed
         acoustic frame features, linearly projected; encoder-only, no
         token embedding at all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal_init


def init_frontend(key, cfg: ModelConfig) -> dict:
    if cfg.frontend == "none":
        return {}
    return {"proj": truncated_normal_init(
        key, (cfg.frontend_dim, cfg.d_model), 1.0,
        jnp.dtype(cfg.param_dtype))}


def patch_grid_mrope(n_patch: int, text_len: int, batch: int) -> jax.Array:
    """Stub M-RoPE position triples: patches on an hxw grid at t=0, text at
    running t after the grid.  [B, n_patch + text_len, 3] int32."""
    side = max(int(n_patch ** 0.5), 1)
    idx = jnp.arange(n_patch)
    patch_pos = jnp.stack([jnp.zeros_like(idx), idx // side, idx % side], -1)
    t0 = 1 + (n_patch - 1) // side
    tpos = t0 + jnp.arange(text_len)
    text_pos = jnp.stack([tpos, tpos, tpos], -1)
    pos = jnp.concatenate([patch_pos, text_pos], 0).astype(jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, n_patch + text_len, 3))


def text_mrope_t0(n_patch: int) -> int:
    """First text `t` coordinate after an n_patch grid (matches
    ``patch_grid_mrope``)."""
    side = max(int(n_patch ** 0.5), 1)
    return 1 + (n_patch - 1) // side


def embed_inputs(params: dict, batch: dict, cfg: ModelConfig,
                 embed_table) -> tuple[jax.Array, jax.Array, jax.Array | None]:
    """-> (x [B, S, D], positions [B, S], mrope_positions|None)."""
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "frame":
        x = batch["frames"].astype(dt) @ params["frontend"]["proj"]
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, pos, None

    tokens = batch["tokens"]
    tok_x = embed_table[tokens].astype(dt)
    if cfg.frontend == "patch":
        px = batch["patches"].astype(dt) @ params["frontend"]["proj"]
        x = jnp.concatenate([px, tok_x], axis=1)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        mrope = batch.get("mrope_positions")
        if mrope is None and cfg.rope == "mrope":
            mrope = patch_grid_mrope(px.shape[1], tok_x.shape[1], b)
        return x, pos, mrope

    b, s, _ = tok_x.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return tok_x, pos, None
